"""Metrics layer of the observability subsystem: a dependency-free,
thread-safe registry of counters, gauges and exponential-bucket histograms.

Design (the Prometheus data model, stdlib-only):

  * a **metric family** is declared once per registry by name (type, help
    text, unit, bucket layout); each distinct label set materializes one
    **child** — ``registry.counter("serve_flushes_total",
    labels={"model": "CNV-w1a1"})`` returns the child for that series and
    is idempotent, so instrumented code never checks "already created?";
  * children are cheap and lock-guarded: ``Counter.inc`` / ``Gauge.set`` /
    ``Histogram.observe`` take one uncontended lock each, safe for the
    serving tier's submit threads;
  * **histograms** record cumulative exponential buckets (``le`` upper
    bounds) plus sum/count, and optionally a bounded **window** of raw
    recent observations — the windowed view is what the serving tier's
    rolling p50/p99 read (exact nearest-rank, identical semantics to the
    old per-engine deques), while the buckets are the exported,
    mergeable representation;
  * two exporters: ``snapshot()``/``to_json()`` (machine-readable, the
    ``repro.obs.report`` CLI and ``METRICS_snapshot.json`` artifact) and
    ``to_prometheus()`` (text exposition served by ``repro.obs.http``).

A process-wide default registry (``default_registry()``) collects the
compile-tier metrics; serving engines default to a private registry per
engine (so a fresh engine's counters start at zero) and accept a shared
one for fleet export (see ``CompiledGraphEngine(metrics_registry=...)``).
"""
from __future__ import annotations

import json
import math
import threading
from collections import deque
from typing import Optional

__all__ = [
    "Counter", "Gauge", "Histogram", "HistogramSnapshot",
    "MetricsRegistry", "default_registry", "exponential_buckets",
    "nearest_rank",
]


def exponential_buckets(start: float = 0.001, factor: float = 2.0,
                        count: int = 28) -> tuple[float, ...]:
    """``count`` upper bounds ``start * factor**i`` (an implicit +Inf
    bucket always follows).  The default spans 1µs-ish to ~2 minutes when
    observations are milliseconds."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError("need start > 0, factor > 1, count >= 1")
    return tuple(start * factor ** i for i in range(count))


def nearest_rank(values, pct: float) -> float:
    """Nearest-rank percentile over a raw sample; nan when empty.  This is
    the exact formula the serving tier's rolling p50/p99 always used."""
    if not values:
        return float("nan")
    vs = sorted(values)
    k = min(len(vs) - 1, max(0, int(round(pct / 100.0 * (len(vs) - 1)))))
    return float(vs[k])


class Counter:
    """Monotonic counter.  ``inc`` by a non-negative amount only."""

    __slots__ = ("labels", "_value", "_lock")

    def __init__(self, labels: dict):
        self.labels = labels
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0, got {amount}")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Point-in-time value; can go up and down."""

    __slots__ = ("labels", "_value", "_lock")

    def __init__(self, labels: dict):
        self.labels = labels
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    @property
    def value(self) -> float:
        return self._value


class HistogramSnapshot:
    """Immutable view of a histogram child: cumulative bucket counts, sum,
    count, and (when the histogram keeps one) the raw rolling window.

    ``percentile`` prefers the exact windowed nearest-rank estimate and
    falls back to the bucket interpolation — so one shared implementation
    serves both the engine's rolling p50/p99 and bucket-only exports.
    """

    __slots__ = ("bounds", "counts", "sum", "count", "window")

    def __init__(self, bounds, counts, total, count, window):
        self.bounds = bounds          # ascending le upper bounds (no +Inf)
        self.counts = counts          # per-bucket (non-cumulative) counts,
        self.sum = total              # len(bounds) + 1 (last is +Inf)
        self.count = count
        self.window = window          # tuple of recent raw values (or ())

    def percentile(self, pct: float) -> float:
        if self.window:
            return nearest_rank(self.window, pct)
        return self.estimate_percentile(pct)

    def estimate_percentile(self, pct: float) -> float:
        """Bucket-interpolated percentile (what a scraped exporter can
        compute): linear within the target bucket, like Prometheus'
        ``histogram_quantile``.  Accuracy is bounded by the bucket width —
        tests/test_obs.py checks it against ``numpy.percentile``."""
        if self.count == 0:
            return float("nan")
        rank = pct / 100.0 * self.count
        cum = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            prev_cum, cum = cum, cum + c
            if cum >= rank:
                if i >= len(self.bounds):        # +Inf bucket: clamp to
                    return self.bounds[-1]       # the last finite bound
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i]
                frac = (rank - prev_cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return self.bounds[-1]

    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")


class Histogram:
    """Exponential-bucket histogram with an optional rolling raw window."""

    __slots__ = ("labels", "bounds", "_counts", "_sum", "_count",
                 "_window", "_lock")

    def __init__(self, labels: dict, buckets: tuple[float, ...],
                 window: int = 0):
        if list(buckets) != sorted(buckets) or len(set(buckets)) != \
                len(buckets):
            raise ValueError("bucket bounds must be strictly ascending")
        self.labels = labels
        self.bounds = tuple(float(b) for b in buckets)
        self._counts = [0] * (len(self.bounds) + 1)   # last = +Inf
        self._sum = 0.0
        self._count = 0
        self._window = deque(maxlen=window) if window else None
        self._lock = threading.Lock()

    def _bucket_index(self, value: float) -> int:
        # first bound >= value (le semantics); bisect over a small tuple
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if value <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def observe(self, value: float) -> None:
        value = float(value)
        if math.isnan(value):
            return
        i = self._bucket_index(value)
        with self._lock:
            self._counts[i] += 1
            self._sum += value
            self._count += 1
            if self._window is not None:
                self._window.append(value)

    def snapshot(self) -> HistogramSnapshot:
        with self._lock:
            return HistogramSnapshot(
                self.bounds, tuple(self._counts), self._sum, self._count,
                tuple(self._window) if self._window is not None else ())

    def percentile(self, pct: float) -> float:
        return self.snapshot().percentile(pct)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum


class _Family:
    __slots__ = ("name", "kind", "help", "unit", "buckets", "window",
                 "children")

    def __init__(self, name, kind, help, unit, buckets, window):
        self.name = name
        self.kind = kind
        self.help = help
        self.unit = unit
        self.buckets = buckets
        self.window = window
        self.children: dict[tuple, object] = {}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


def _label_key(labels: Optional[dict]) -> tuple:
    return tuple(sorted((labels or {}).items()))


class MetricsRegistry:
    """Thread-safe name -> metric-family table with label support."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    # ----------------------------------------------------------- creation

    def _metric(self, kind: str, name: str, help: str, unit: str,
                labels: Optional[dict], buckets=None, window: int = 0):
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(name, kind, help, unit, buckets, window)
                self._families[name] = fam
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}, "
                    f"cannot re-register as {kind}")
            child = fam.children.get(key)
            if child is None:
                if kind == "histogram":
                    child = Histogram(dict(key), fam.buckets, fam.window)
                else:
                    child = _KINDS[kind](dict(key))
                fam.children[key] = child
            return child

    def counter(self, name: str, *, help: str = "", unit: str = "",
                labels: Optional[dict] = None) -> Counter:
        return self._metric("counter", name, help, unit, labels)

    def gauge(self, name: str, *, help: str = "", unit: str = "",
              labels: Optional[dict] = None) -> Gauge:
        return self._metric("gauge", name, help, unit, labels)

    def histogram(self, name: str, *, help: str = "", unit: str = "",
                  labels: Optional[dict] = None,
                  buckets: Optional[tuple] = None,
                  window: int = 0) -> Histogram:
        """``buckets`` defaults to ``exponential_buckets()``; ``window``
        (observations) enables the exact rolling-percentile view.  Bucket
        layout and window are family-wide: the first declaration wins."""
        if buckets is None:
            buckets = exponential_buckets()
        return self._metric("histogram", name, help, unit, labels,
                            tuple(buckets), int(window))

    def get(self, name: str, labels: Optional[dict] = None):
        """Existing child or None (never creates)."""
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                return None
            return fam.children.get(_label_key(labels))

    # ------------------------------------------------------------ exports

    def snapshot(self) -> dict:
        """{name: {type, help, unit, series: [...]}} — the JSON schema the
        report CLI, the /metrics.json endpoint and the CI artifact share.
        Histogram series carry buckets + count/sum plus pre-computed
        p50/p90/p99 (windowed when available, bucket estimate otherwise)."""
        with self._lock:
            fams = [(f, list(f.children.values()))
                    for f in self._families.values()]
        out = {}
        for fam, children in fams:
            series = []
            for child in children:
                if fam.kind == "histogram":
                    s = child.snapshot()
                    series.append({
                        "labels": child.labels,
                        "count": s.count,
                        "sum": s.sum,
                        "buckets": [[b, c] for b, c in
                                    zip(list(s.bounds) + ["+Inf"], s.counts)],
                        "p50": s.percentile(50),
                        "p90": s.percentile(90),
                        "p99": s.percentile(99),
                    })
                else:
                    series.append({"labels": child.labels,
                                   "value": child.value})
            out[fam.name] = {"type": fam.kind, "help": fam.help,
                             "unit": fam.unit, "series": series}
        return out

    def to_json(self, **dump_kw) -> str:
        dump_kw.setdefault("indent", 2)
        dump_kw.setdefault("sort_keys", True)

        def _default(o):
            f = float(o)
            return f

        return json.dumps(self.snapshot(), default=_default, **dump_kw)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        def esc(v):
            return str(v).replace("\\", r"\\").replace('"', r'\"') \
                .replace("\n", r"\n")

        def fmt_labels(labels, extra=None):
            items = list(sorted(labels.items())) + (extra or [])
            if not items:
                return ""
            return "{" + ",".join(f'{k}="{esc(v)}"' for k, v in items) + "}"

        def num(v):
            if isinstance(v, float) and math.isinf(v):
                return "+Inf" if v > 0 else "-Inf"
            return repr(float(v)) if isinstance(v, float) else str(v)

        lines = []
        for name, fam in sorted(self.snapshot().items()):
            if fam["help"]:
                lines.append(f"# HELP {name} {esc(fam['help'])}")
            lines.append(f"# TYPE {name} {fam['type']}")
            for s in fam["series"]:
                if fam["type"] == "histogram":
                    cum = 0
                    for le, c in s["buckets"]:
                        cum += c
                        lines.append(
                            f"{name}_bucket"
                            f"{fmt_labels(s['labels'], [('le', le)])} {cum}")
                    lines.append(
                        f"{name}_sum{fmt_labels(s['labels'])} "
                        f"{num(s['sum'])}")
                    lines.append(
                        f"{name}_count{fmt_labels(s['labels'])} "
                        f"{s['count']}")
                else:
                    lines.append(
                        f"{name}{fmt_labels(s['labels'])} {num(s['value'])}")
        return "\n".join(lines) + "\n"

    # --------------------------------------------------------------- misc

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._families)

    def clear(self) -> None:
        """Drop every family (tests / long-lived default registry only)."""
        with self._lock:
            self._families.clear()


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry (compile-tier metrics, the
    ``--metrics-port`` endpoint, the CI snapshot artifact)."""
    return _DEFAULT
