"""Request tracing: lightweight spans with parent/child links + JSONL sink.

A ``Span`` is a named time interval with attributes; a ``Tracer`` mints
span/trace ids and hands finished spans to a **sink** (any callable taking
the span record dict — ``JsonlSink`` appends one JSON object per line,
``ListSink`` collects in memory for tests).

Two ways to produce spans:

  * live, via the context-manager API (monotonic clock)::

        with tracer.span("flush", queue_depth=12) as sp:
            with tracer.span("dispatch", parent=sp):
                ...

  * retroactively, via ``emit(name, t0, t1, ...)`` — the serving engine
    already timestamps every request (submit/dispatch/complete), so at
    flush time it emits the submit->queue->dispatch->sync->complete spans
    from those timestamps without adding clock reads to the hot path.

Disabled tracing is **free**: instrumented code guards on
``tracer is not None and tracer.enabled`` (the serving engine folds this
into one attribute check), so the submit hot path performs zero
allocations attributable to this module — proven by the tracemalloc test
in tests/test_obs.py.  Sinks are locked; span records are plain dicts::

    {"name", "trace", "span", "parent", "t0", "t1", "dur_ms", ...attrs}
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from typing import Callable, Optional

__all__ = ["Span", "Tracer", "JsonlSink", "ListSink"]

_ids = itertools.count(1)
_ids_lock = threading.Lock()


def _new_id() -> str:
    with _ids_lock:
        return f"{next(_ids):08x}"


class Span:
    """One named interval.  Ends (and reaches the sink) on ``end()`` or
    context-manager exit; attributes are set at creation or via ``set``."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "t0", "t1",
                 "attrs", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, trace_id: str,
                 parent_id: Optional[str], t0: float, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.span_id = _new_id()
        self.parent_id = parent_id
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs = attrs

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def end(self, t1: Optional[float] = None) -> None:
        if self.t1 is not None:        # idempotent: first end wins
            return
        self.t1 = self._tracer.clock() if t1 is None else t1
        self._tracer._record(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self.end()

    def to_record(self) -> dict:
        rec = {"name": self.name, "trace": self.trace_id,
               "span": self.span_id, "parent": self.parent_id,
               "t0": self.t0, "t1": self.t1,
               "dur_ms": None if self.t1 is None
               else (self.t1 - self.t0) * 1e3}
        rec.update(self.attrs)
        return rec


class Tracer:
    """Mints spans, stamps ids, forwards finished spans to the sink.

    ``enabled=False`` turns every guard off — instrumented code must check
    ``tracer.enabled`` (or hold ``tracer=None``) before touching the span
    API, which is what keeps disabled tracing allocation-free.
    ``clock`` defaults to ``time.monotonic``; the serving engine emits
    retro spans with explicit ``time.monotonic()`` timestamps (all of a
    request's spans then share the live-span clock, and a wall-clock step
    can never produce a negative span duration).
    """

    def __init__(self, sink: Optional[Callable[[dict], None]] = None,
                 *, enabled: bool = True,
                 clock: Callable[[], float] = time.monotonic):
        self.sink = sink
        self.enabled = enabled
        self.clock = clock
        self.n_spans = 0

    # ------------------------------------------------------------ spans

    def new_trace_id(self) -> str:
        return _new_id()

    def span(self, name: str, *, parent: Optional[Span] = None,
             trace_id: Optional[str] = None, **attrs) -> Span:
        """Start a live span now (context manager; ends on exit)."""
        if parent is not None:
            trace_id = parent.trace_id
            parent_id = parent.span_id
        else:
            trace_id = trace_id or _new_id()
            parent_id = None
        return Span(self, name, trace_id, parent_id, self.clock(), attrs)

    def emit(self, name: str, t0: float, t1: float, *,
             trace_id: Optional[str] = None,
             parent_id: Optional[str] = None, **attrs) -> str:
        """Record a completed interval from explicit timestamps; returns
        the new span id (to parent further retro spans under it)."""
        sp = Span(self, name, trace_id or _new_id(), parent_id, t0, attrs)
        sp.end(t1)
        return sp.span_id

    def _record(self, span: Span) -> None:
        self.n_spans += 1
        if self.sink is not None:
            self.sink(span.to_record())


class JsonlSink:
    """Appends one JSON object per span to ``path`` (locked, line-atomic).

    The file handle stays open between spans; ``close()`` (or context
    exit) flushes.  Floats land as plain JSON numbers — downstream tools
    (``jq``, pandas) read the file directly.
    """

    def __init__(self, path):
        self.path = str(path)
        self._lock = threading.Lock()
        self._fh = open(self.path, "a", encoding="utf-8")

    def __call__(self, record: dict) -> None:
        line = json.dumps(record, sort_keys=True)
        with self._lock:
            if self._fh is None:
                raise ValueError(f"JsonlSink({self.path!r}) is closed")
            self._fh.write(line + "\n")

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ListSink(list):
    """In-memory sink (tests): a list of span record dicts."""

    def __call__(self, record: dict) -> None:
        self.append(record)
