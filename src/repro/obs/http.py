"""Stdlib HTTP metrics endpoint: Prometheus text + JSON snapshot.

``start_metrics_server(registry, port)`` spins a daemon
``ThreadingHTTPServer`` serving

  * ``GET /metrics``       — Prometheus text exposition 0.0.4
  * ``GET /metrics.json``  — the registry's JSON snapshot (what
                             ``python -m repro.obs.report`` renders)
  * ``GET /healthz``       — 200 "ok"

and returns a handle with ``.port`` (useful with ``port=0``) and
``.close()``.  Wired into ``python -m repro.launch.serve --metrics-port``.
"""
from __future__ import annotations

import logging
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

log = logging.getLogger("repro.obs")

__all__ = ["MetricsServer", "start_metrics_server"]


class _Handler(BaseHTTPRequestHandler):
    registry = None                   # set on the per-server subclass

    def _send(self, code: int, body: str, ctype: str) -> None:
        data = body.encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", ctype + "; charset=utf-8")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def do_GET(self):                 # noqa: N802 (stdlib handler API)
        path = self.path.split("?", 1)[0]
        if path in ("/metrics", "/"):
            self._send(200, self.registry.to_prometheus(),
                       "text/plain; version=0.0.4")
        elif path == "/metrics.json":
            self._send(200, self.registry.to_json(), "application/json")
        elif path == "/healthz":
            self._send(200, "ok\n", "text/plain")
        else:
            self._send(404, f"not found: {path}\n", "text/plain")

    def log_message(self, fmt, *args):   # route to logging, not stderr
        log.debug("metrics http: " + fmt, *args)


class MetricsServer:
    """A running metrics endpoint; ``close()`` stops it."""

    def __init__(self, registry, port: int = 9100, host: str = "0.0.0.0"):
        handler = type("_BoundHandler", (_Handler,), {"registry": registry})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-http",
            daemon=True)
        self._thread.start()
        log.info("metrics endpoint on http://%s:%d/metrics",
                 self.host, self.port)

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def start_metrics_server(registry=None, port: int = 9100,
                         host: str = "0.0.0.0") -> MetricsServer:
    """Serve ``registry`` (default: the process-wide one) over HTTP."""
    if registry is None:
        from .metrics import default_registry
        registry = default_registry()
    return MetricsServer(registry, port=port, host=host)
