"""repro.obs — unified observability: metrics, tracing, kernel profiling.

Three dependency-free layers over the compile/serve tiers:

  * ``metrics``  — thread-safe ``MetricsRegistry`` of counters, gauges and
                   exponential-bucket histograms with labels; JSON +
                   Prometheus exporters; process-wide default registry.
                   ``python -m repro.obs.report`` renders a snapshot.
  * ``trace``    — ``Span``/``Tracer`` (context-manager or retroactive
                   ``emit``), parent/child links, JSONL sink; wired through
                   the serving request lifecycle (submit -> queue -> flush
                   -> dispatch -> sync -> complete).  Disabled tracing adds
                   zero allocations to the submit hot path.
  * ``profile``  — opt-in per-segment timing of a ``CompiledPlan``
                   (``plan.profile()``), joined with the analysis cost
                   report into measured ms / MACs/s / achieved-vs-minimal
                   bytes / requant path per fused segment.

``http.start_metrics_server`` serves the Prometheus text format from a
stdlib HTTP server (``python -m repro.launch.serve --metrics-port``).
"""
from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    HistogramSnapshot,
    MetricsRegistry,
    default_registry,
    exponential_buckets,
    nearest_rank,
)
from .http import MetricsServer, start_metrics_server  # noqa: F401
from .profile import (  # noqa: F401
    PlanProfile, SegmentProfile, profile_plan, time_fn, time_fns)
from .trace import JsonlSink, ListSink, Span, Tracer  # noqa: F401
from . import http  # noqa: F401

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "HistogramSnapshot",
    "JsonlSink",
    "ListSink",
    "MetricsRegistry",
    "MetricsServer",
    "PlanProfile",
    "SegmentProfile",
    "Span",
    "Tracer",
    "default_registry",
    "exponential_buckets",
    "nearest_rank",
    "profile_plan",
    "start_metrics_server",
    "time_fn",
    "time_fns",
]
