"""PTQ calibration: derive static (scale, zero_point) from sample batches.

Three estimators (min-max, percentile, MSE-grid) feeding
``core.quant_ops.scale_from_minmax``.  Used by the PTQ example and the
serving weight-quantization path.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quant_ops import quant, scale_from_minmax

from .config import TensorQuant


def minmax_stats(samples):
    """Running min/max over a list of arrays."""
    lo = jnp.asarray(jnp.inf)
    hi = jnp.asarray(-jnp.inf)
    for s in samples:
        lo = jnp.minimum(lo, s.min())
        hi = jnp.maximum(hi, s.max())
    return lo, hi


def percentile_stats(samples, pct=99.9):
    flat = jnp.concatenate([jnp.ravel(s) for s in samples])
    lo = jnp.percentile(flat, 100 - pct)
    hi = jnp.percentile(flat, pct)
    return lo, hi


def calibrate_minmax(samples, tq: TensorQuant):
    lo, hi = minmax_stats(samples)
    return scale_from_minmax(lo, hi, tq.bit_width, signed=tq.signed,
                             narrow=tq.narrow, symmetric=tq.symmetric)


def calibrate_percentile(samples, tq: TensorQuant, pct=99.9):
    lo, hi = percentile_stats(samples, pct)
    return scale_from_minmax(lo, hi, tq.bit_width, signed=tq.signed,
                             narrow=tq.narrow, symmetric=tq.symmetric)


def calibrate_mse(samples, tq: TensorQuant, n_grid=40):
    """Search the clipping range minimizing quantization MSE."""
    flat = jnp.concatenate([jnp.ravel(s) for s in samples])
    amax = jnp.max(jnp.abs(flat))
    best = (None, jnp.inf)
    for frac in jnp.linspace(0.3, 1.0, n_grid):
        s, z = scale_from_minmax(-amax * frac, amax * frac, tq.bit_width,
                                 signed=tq.signed, narrow=tq.narrow,
                                 symmetric=tq.symmetric)
        err = jnp.mean((quant(flat, s, z, tq.bit_width, signed=tq.signed,
                              narrow=tq.narrow) - flat) ** 2)
        if float(err) < float(best[1]):
            best = ((s, z), err)
    return best[0]
