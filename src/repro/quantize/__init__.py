"""repro.quantize — QONNX-semantics QAT/PTQ integration for JAX models."""
from .config import QuantRecipe, TensorQuant  # noqa: F401
from .layers import quant_act, quant_weight, qlinear  # noqa: F401
from . import calibrate  # noqa: F401
