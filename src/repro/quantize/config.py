"""Quantization recipes: how the paper's §II conventions apply to a model.

A ``TensorQuant`` mirrors the attribute set of the QONNX Quant operator
(bit_width / signed / narrow / rounding_mode) plus granularity; a
``QuantRecipe`` bundles the per-tensor-kind choices the paper describes:

  * weights     — symmetric, narrow, channel-wise (avoid runtime extra term)
  * activations — asymmetric allowed, tensor-wise, integer zero point
  * bias        — s_bias = s_w * s_in (inherited, never independent)
  * kv cache    — symmetric per-head (serving extension)

Recipes are static pytree-free dataclasses → safe as jit static args.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class TensorQuant:
    bit_width: float = 8.0
    signed: bool = True
    narrow: bool = False
    symmetric: bool = True
    channelwise: bool = False      # scale per output channel (weights)
    rounding_mode: str = "ROUND"

    def describe(self) -> str:
        g = "chan" if self.channelwise else "tensor"
        s = "sym" if self.symmetric else "asym"
        return f"{self.bit_width}b/{s}/{g}/{'n' if self.narrow else 'w'}"


@dataclass(frozen=True)
class QuantRecipe:
    """Paper-§II-conventional QAT recipe.  ``enabled=False`` => pure float."""
    enabled: bool = False
    weights: TensorQuant = field(default_factory=lambda: TensorQuant(
        bit_width=8, symmetric=True, narrow=True, channelwise=True))
    acts: TensorQuant = field(default_factory=lambda: TensorQuant(
        bit_width=8, symmetric=True, narrow=False, channelwise=False))
    kv_cache_bits: Optional[float] = None     # None = float cache
    quantize_embeddings: bool = False

    @staticmethod
    def w_a(w_bits: float, a_bits: float, **kw) -> "QuantRecipe":
        """Convenience: the paper's CNV-wXaY notation."""
        return QuantRecipe(
            enabled=True,
            weights=TensorQuant(bit_width=w_bits, symmetric=True, narrow=True,
                                channelwise=True),
            acts=TensorQuant(bit_width=a_bits, symmetric=True, narrow=False,
                             channelwise=False),
            **kw)

    def tag(self) -> str:
        if not self.enabled:
            return "fp"
        return (f"w{self.weights.bit_width:g}a{self.acts.bit_width:g}"
                + (f"kv{self.kv_cache_bits:g}" if self.kv_cache_bits else ""))


FP32 = QuantRecipe(enabled=False)
W8A8 = QuantRecipe.w_a(8, 8)
W4A8 = QuantRecipe.w_a(4, 8)
W4A4 = QuantRecipe.w_a(4, 4)
W2A2 = QuantRecipe.w_a(2, 2)
