"""Fake-quant building blocks used inside the model substrate.

Dynamic quantization per paper §V: "scale as a function of x" — scales are
computed at runtime from the tensor being quantized (weights re-derive their
channel scale each step; activations their tensor scale).  This keeps the
parameter pytree identical between float and QAT runs (no learnable scales
in the checkpoint), which matters for elastic restarts, while remaining a
faithful realization of the QONNX Quant op with runtime scale inputs.

Gradients flow via the STE (core/ste.py).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quant_ops import max_int
from repro.core.ste import quant_ste

from .config import QuantRecipe, TensorQuant


def _dynamic_scale(x, tq: TensorQuant, *, channel_axis=None):
    """max-abs symmetric scale; per-channel when requested."""
    if tq.channelwise and channel_axis is not None:
        axes = tuple(i for i in range(x.ndim) if i != channel_axis % x.ndim)
        amax = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(x))
    bound = max_int(tq.signed, tq.narrow, tq.bit_width)
    return jnp.maximum(amax.astype(jnp.float32), 1e-8) / bound


def quant_weight(w, tq: TensorQuant):
    """Fake-quant a weight (..., out_features): channel-wise on last axis."""
    s = _dynamic_scale(w, tq, channel_axis=-1)
    return quant_ste(w, s.astype(w.dtype), jnp.zeros((), w.dtype),
                     jnp.asarray(tq.bit_width), tq.signed, tq.narrow,
                     tq.rounding_mode)


def quant_act(x, tq: TensorQuant):
    """Fake-quant an activation tensor (tensor-wise dynamic scale)."""
    s = _dynamic_scale(x, tq)
    return quant_ste(x, s.astype(x.dtype), jnp.zeros((), x.dtype),
                     jnp.asarray(tq.bit_width), tq.signed, tq.narrow,
                     tq.rounding_mode)


def qlinear(x, w, b=None, recipe: QuantRecipe | None = None):
    """Linear layer with QONNX fake-quant at both operands.

    x: (..., K); w: (K, N) (or (..., K, N) for stacked/batched weights with
    matching leading dims); b: (N,).  Bias is NOT independently quantized —
    per paper §II it inherits s_bias = s_w * s_in, which fake-quant realizes
    automatically since the product grid contains the bias grid.
    """
    if recipe is not None and recipe.enabled:
        w = quant_weight(w, recipe.weights)
        x = quant_act(x, recipe.acts)
    y = jnp.matmul(x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def qeinsum(spec, x, w, recipe: QuantRecipe | None = None):
    """Einsum variant of qlinear (used for attention projections / MoE)."""
    if recipe is not None and recipe.enabled:
        w = quant_weight(w, recipe.weights)
        x = quant_act(x, recipe.acts)
    return jnp.einsum(spec, x, w.astype(x.dtype))


def quant_kv(k, v, bits):
    """Quantize KV-cache entries symmetrically per head-dim vector; returns
    fake-quant floats (storage realization picks the carrier — DESIGN.md §3)."""
    if bits is None:
        return k, v
    tq = TensorQuant(bit_width=bits, symmetric=True, narrow=False)
    sk = _dynamic_scale(k, tq)
    sv = _dynamic_scale(v, tq)
    k = quant_ste(k, sk.astype(k.dtype), jnp.zeros((), k.dtype),
                  jnp.asarray(float(bits)), True, False, "ROUND")
    v = quant_ste(v, sv.astype(v.dtype), jnp.zeros((), v.dtype),
                  jnp.asarray(float(bits)), True, False, "ROUND")
    return k, v
