"""Checkpoint manager: atomic writes, resume, retention, async save.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, plus <dir>/LATEST.
Atomicity: write into ``step_<N>.tmp`` then ``os.rename`` (POSIX-atomic);
LATEST is written last, so a crash mid-save never corrupts the resume path.
Mesh independence: leaves are saved as host numpy arrays (fully addressable
gather) and resharded on load against whatever shardings the *current* mesh
provides — this is what makes elastic restarts (512 -> 256 chips) work.
Multi-host: only process 0 writes (single-controller assumption documented);
on a real multi-controller cluster this becomes per-host shard files keyed
by process_index — the manifest format already carries the field.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3, async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    # ----------------------------------------------------------- saving

    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        """state: arbitrary pytree (params/opt/data-state).  Blocks unless
        async_save; a second save waits for the previous one (back-pressure
        instead of unbounded memory growth)."""
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        if self.async_save:
            self.wait()
            self._pending = threading.Thread(
                target=self._write, args=(step, host_state, extra or {}),
                daemon=True)
            self._pending.start()
        else:
            self._write(step, host_state, extra or {})

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_state, extra: dict):
        if jax.process_index() != 0:
            return
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f"step_{step:010d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_state)
        np.savez(tmp / "arrays.npz", **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "process_index": jax.process_index(),
            "n_leaves": len(flat),
            "extra": extra,
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic
        (self.dir / "LATEST.tmp").write_text(str(step))
        os.rename(self.dir / "LATEST.tmp", self.dir / "LATEST")
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ---------------------------------------------------------- loading

    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        latest = self.dir / "LATEST"
        if latest.exists():
            s = int(latest.read_text())
            if (self.dir / f"step_{s:010d}" / "manifest.json").exists():
                return s
        steps = self.all_steps()                   # LATEST lost: scan
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, shardings: Any = None):
        """Restore into the structure of ``target`` (pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: matching pytree of shardings for
        the *current* mesh (reshard-on-load)."""
        d = self.dir / f"step_{step:010d}"
        with np.load(d / "arrays.npz") as z:
            flat_saved = {k: z[k] for k in z.files}
        flat_target = _flatten(target)
        missing = set(flat_target) - set(flat_saved)
        if missing:
            raise ValueError(f"checkpoint step {step} missing leaves: "
                             f"{sorted(missing)[:5]}...")
        values = {k: flat_saved[k] for k in flat_target}
        leaves_paths = jax.tree_util.tree_flatten_with_path(target)
        keys = list(_flatten(target).keys())
        new_leaves = [values[k] for k in keys]
        restored = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target), new_leaves)
        if shardings is not None:
            restored = jax.tree.map(
                lambda v, s: jax.device_put(v, s), restored, shardings)
        return restored

    def manifest(self, step: int) -> dict:
        d = self.dir / f"step_{step:010d}"
        return json.loads((d / "manifest.json").read_text())
