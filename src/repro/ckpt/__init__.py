"""repro.ckpt — atomic, mesh-independent checkpointing."""
from .manager import CheckpointManager  # noqa: F401
