"""Compiled vs interpreted executor: wall-time over the zoo graphs.

The headline number for the compile tier (core/compile.py): steady-state
µs/call of the single jitted plan vs node-by-node Python dispatch, plus the
fused-segment census.  The quantized-matmul-dominated graphs (TFC family)
dispatch their MatMuls onto the integer Pallas kernels; conv-dominated
graphs win mostly from removing the per-node dispatch + re-quantizing
constant weights every call.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import execute, transforms
from repro.core.compile import compile_graph
from repro.models import zoo

CASES = [
    ("TFC-w2a2", (1, 784)),
    ("TFC-w1a1", (1, 784)),
    ("CNV-w2a2", (1, 3, 32, 32)),
]


def _time(fn, n=5):
    fn()                                    # warm (trace + compile)
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def run(cases=None) -> list[str]:
    rows = []
    for name, shape in (CASES if cases is None else cases):
        g = zoo.ZOO[name]()
        gc = transforms.cleanup(g)
        t0 = time.perf_counter()
        plan = compile_graph(g)
        compile_us = (time.perf_counter() - t0) * 1e6
        x = np.random.RandomState(0).randn(*shape).astype(np.float32)
        out_name = gc.output_names[0]

        us_interp = _time(lambda: np.asarray(execute(gc, {"x": x})[out_name]))
        us_comp = _time(lambda: np.asarray(
            plan({"x": x})[plan.graph.output_names[0]]))
        fused = ";".join(f"{k}={v}" for k, v in sorted(
            plan.fused_counts.items()))
        rows.append(
            f"compile/{name}_interpreted,{us_interp:.0f},node_by_node_oracle")
        rows.append(
            f"compile/{name}_compiled,{us_comp:.0f},"
            f"speedup={us_interp / us_comp:.1f}x;{fused};"
            f"compile_us={compile_us:.0f}")

        # batched serving amortizes the fixed per-call overhead further
        xb = np.random.RandomState(1).randn(8, *shape[1:]).astype(np.float32)
        us_b = _time(lambda: np.asarray(
            plan({"x": xb})[plan.graph.output_names[0]]))
        rows.append(f"compile/{name}_compiled_b8,{us_b:.0f},"
                    f"us_per_sample={us_b / 8:.0f}")
    return rows


QUICK_CASES = [("TFC-w2a2", (1, 784)), ("TFC-w1a1", (1, 784))]


def main(argv=None) -> int:
    """CLI used by the CI smoke job: exit 0 iff every row was produced.

        python benchmarks/bench_compile.py [--quick]
    """
    import argparse

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="TFC-only cases (fast enough for CI smoke)")
    args = ap.parse_args(argv)
    cases = QUICK_CASES if args.quick else CASES
    rows = run(cases)
    for row in rows:
        print(row)
    return 0 if len(rows) == 3 * len(cases) else 1


if __name__ == "__main__":        # PYTHONPATH=src python benchmarks/bench_compile.py
    raise SystemExit(main())
