"""Compiled vs interpreted executor: wall-time over the zoo graphs.

The headline number for the compile tier (core/compile.py): steady-state
µs/call of the single jitted plan vs node-by-node Python dispatch, plus the
fused-segment census.  With the lowering-rule registry both the quantized
matmuls (TFC family) and the convolutions (CNV / MobileNet) dispatch onto
the integer Pallas kernels; only shape-shuffles and pooling remain on the
interpreted fallback.

``--json PATH`` writes the same measurements machine-readably (per-model
wall times, speedup, fused-segment counts) so the perf trajectory is
tracked across PRs; ``--check-conv MODEL`` is the CI regression gate that
asserts the conv lowering still fires (≥1 conv segment fused, 0 Conv nodes
left interpreted); ``--check-grouped MODEL`` additionally gates the
grouped/depthwise kernel tier (every group>1 conv on the dedicated
kernels, 0 block-diagonal carriers, cost-report MACs below the
dense-equivalent block-diagonal count by exactly the reclaimed amount);
``--check-integer-requant MODEL`` gates the integer-only dyadic
requantization path (every kernel segment on the int32 multiplier+shift
epilogue, coverage recorded in the JSON artifact);
``--check-fusion MODEL`` gates cross-segment fusion (≥1 fused boundary
segment on an integer inter-segment carrier, positive boundary
bytes-saved, 0 interpreted MaxPool/Add, fused output bit-identical to
the ``use_fusion=False`` plan).  Each per-model JSON record also carries
``fusion``: the plan's boundary census (``CompiledPlan.fusion_stats``).

Per model the JSON record also carries ``requant``: the plan's
integer-path coverage (``CompiledPlan.requant_stats``) plus the measured
epilogue speedup vs the same plan compiled with
``use_integer_requant=False`` (the fp32 dequant->round->requant chain) —
and ``profile``: the per-segment measured table (``CompiledPlan.profile``
joined with the analysis cost report: ms / MACs/s / minimal-vs-achieved
bytes / requant path per fused segment).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import execute, transforms
from repro.core.compile import compile_graph
from repro.models import zoo
from repro.obs.profile import time_fn, time_fns

CASES = [
    ("TFC-w2a2", (1, 784)),
    ("TFC-w1a1", (1, 784)),
    ("CNV-w2a2", (1, 3, 32, 32)),
]

QUICK_CASES = [("TFC-w2a2", (1, 784)), ("TFC-w1a1", (1, 784))]


def _time(fn, n=5):
    """Best-of-``n`` µs/call via the shared obs.profile harness."""
    return time_fn(fn, n) * 1e6


def run_detailed(cases=None) -> tuple[list[str], dict]:
    """Benchmark ``cases``; returns (CSV rows, per-model record dict)."""
    rows, records = [], {}
    for name, shape in (CASES if cases is None else cases):
        g = zoo.ZOO[name]()
        gc = transforms.cleanup(g)
        t0 = time.perf_counter()
        plan = compile_graph(g)
        compile_us = (time.perf_counter() - t0) * 1e6
        x = np.random.RandomState(0).randn(*shape).astype(np.float32)
        out_name = gc.output_names[0]

        # block_until_ready, not np.asarray: the plan returns un-forced
        # device arrays (async dispatch — what the serving tier pipelines
        # on), so timing must wait for the *compute*, not just the enqueue;
        # a host copy would also pollute the measurement
        us_interp = _time(lambda: jax.block_until_ready(
            execute(gc, {"x": x})[out_name]))
        us_comp = _time(lambda: jax.block_until_ready(
            plan({"x": x})[plan.graph.output_names[0]]))
        fused = ";".join(f"{k}={v}" for k, v in sorted(
            plan.fused_counts.items()))
        rows.append(
            f"compile/{name}_interpreted,{us_interp:.0f},node_by_node_oracle")
        rows.append(
            f"compile/{name}_compiled,{us_comp:.0f},"
            f"speedup={us_interp / us_comp:.1f}x;{fused};"
            f"compile_us={compile_us:.0f}")

        # integer-requant coverage + epilogue speedup vs the fp32 baseline:
        # the same graph compiled with the integer path disabled isolates
        # the dequant->round->requant chain the dyadic path eliminates
        rq = plan.requant_stats()
        plan_fp32 = compile_graph(g, use_integer_requant=False)
        us_fp32 = _time(lambda: jax.block_until_ready(
            plan_fp32({"x": x})[plan_fp32.graph.output_names[0]]))
        rows.append(
            f"compile/{name}_fp32_requant,{us_fp32:.0f},"
            f"int_coverage={rq['coverage']:.2f};"
            f"epilogue_speedup={us_fp32 / us_comp:.2f}x;"
            f"fp32_ops_eliminated={rq['fp32_ops_eliminated']}")

        # batched serving amortizes the fixed per-call overhead further
        xb = np.random.RandomState(1).randn(8, *shape[1:]).astype(np.float32)
        us_b = _time(lambda: jax.block_until_ready(
            plan({"x": xb})[plan.graph.output_names[0]]))
        rows.append(f"compile/{name}_compiled_b8,{us_b:.0f},"
                    f"us_per_sample={us_b / 8:.0f}")
        records[name] = {
            "interp_us": round(us_interp, 1),
            "compiled_us": round(us_comp, 1),
            "speedup": round(us_interp / us_comp, 2),
            "compile_us": round(compile_us, 1),
            "fused_counts": dict(sorted(plan.fused_counts.items())),
            "interp_op_counts": dict(sorted(plan.interp_op_counts().items())),
            "batch8_us": round(us_b, 1),
            "batch8_us_per_sample": round(us_b / 8, 1),
            "requant": {
                **rq,
                "fp32_requant_us": round(us_fp32, 1),
                "epilogue_speedup": round(us_fp32 / us_comp, 3),
            },
            # cross-segment fusion census: fused boundary segments, integer
            # carriers, inter-segment bytes saved per call vs fp32
            "fusion": plan.fusion_stats(),
            # per-segment measured profile (ms, MACs/s, bytes, requant path
            # per fused segment joined with the analysis cost report)
            "profile": plan.profile(
                {"x": x}, repeats=5).to_json(),
        }
    return rows, records


def run(cases=None) -> list[str]:
    return run_detailed(cases)[0]


def check_conv_lowering(name: str) -> dict:
    """Regression gate: ``name`` must compile with its convs on the kernel
    tier (≥1 conv segment fused, 0 Conv nodes on the interpreted fallback).
    Returns a record; record["ok"] is the verdict."""
    plan = compile_graph(zoo.ZOO[name]())
    conv_fused = sum(v for k, v in plan.fused_counts.items()
                     if k.startswith("quant_conv"))
    conv_interp = plan.interp_op_counts().get("Conv", 0)
    return {
        "model": name,
        "conv_segments_fused": conv_fused,
        "conv_nodes_interpreted": conv_interp,
        "fused_counts": dict(sorted(plan.fused_counts.items())),
        "ok": conv_fused >= 1 and conv_interp == 0,
    }


def check_grouped_lowering(name: str) -> dict:
    """Regression gate for the grouped/depthwise kernel tier.

    ``name`` (MobileNet-w4a4 in CI) must compile with

      * every Conv fused on the kernel tier (0 interpreted),
      * every group>1 conv on the dedicated grouped/depthwise kernels —
        0 block-diagonal dense carriers left for grouped layers,
      * a positive reclaimed-MAC count whose analysis-side mirror agrees:
        the cost report's MAC total (true I/g·kH·kW contraction, no
        O(groups) inflation) must sit below the dense-equivalent
        block-diagonal number by exactly the plan's reclaimed MACs.
    """
    from repro.analysis import infer_cost

    g = zoo.ZOO[name]()
    plan = compile_graph(g)
    n_convs = sum(1 for n in plan.graph.nodes if n.op_type == "Conv")
    conv_fused = sum(v for k, v in plan.fused_counts.items()
                     if k.startswith("quant_conv"))
    conv_interp = plan.interp_op_counts().get("Conv", 0)
    stats = plan.grouped_conv_stats()
    report = infer_cost(plan.graph, ga=plan.analysis)
    macs_drop = report.dense_equiv_macs - report.macs
    return {
        "model": name,
        "conv_nodes": n_convs,
        "conv_segments_fused": conv_fused,
        "conv_nodes_interpreted": conv_interp,
        "fused_counts": dict(sorted(plan.fused_counts.items())),
        "grouped_stats": stats,
        "report_macs": report.macs,
        "dense_equiv_macs": report.dense_equiv_macs,
        "ok": (conv_fused == n_convs and conv_interp == 0 and
               stats["grouped_segments"] >= 1 and
               stats["block_diagonal_grouped"] == 0 and
               stats["reclaimed_macs"] > 0 and
               macs_drop == stats["reclaimed_macs"]),
    }


def check_integer_requant(name: str) -> dict:
    """Regression gate for the integer-only dyadic requantization path.

    ``name`` (TFC-w1a1 / CNV-w1a1 in CI) must compile with **every**
    kernel-family segment on the int32 multiplier+shift epilogue —
    coverage 1.0, zero fp32-requant segments, and a positive count of
    eliminated fp32 epilogue ops.  The zoo's scales are exact powers of
    two by construction, so anything less means the dyadic detection or
    the exactness proof regressed.
    """
    plan = compile_graph(zoo.ZOO[name]())
    stats = plan.requant_stats()
    return {
        "model": name,
        "requant_stats": stats,
        "fused_counts": dict(sorted(plan.fused_counts.items())),
        "ok": (stats["kernel_segments"] >= 1 and
               stats["fp32_segments"] == 0 and
               stats["coverage"] == 1.0 and
               stats["fp32_ops_eliminated"] > 0),
    }


def check_fusion(name: str) -> dict:
    """Regression gate for cross-segment fusion with integer carriers.

    ``name`` (CNV-w1a1 in CI) must compile with

      * ≥1 fused boundary segment (an epilogue-absorbed MaxPool / Add /
        Concat successor) and ≥1 integer inter-segment carrier,
      * a positive inter-segment bytes-saved count (the HBM round-trips
        the integer carriers eliminate vs fp32 boundaries),
      * **zero** interpreted MaxPool and Add nodes — CNV's pooling and any
        residual adds must ride inside fused segments, not the fallback,
      * the fused plan bit-identical to the same graph compiled with
        ``use_fusion=False`` on a fixed input (fusion is a layout
        optimization, never a numerics change).
    """
    g = zoo.ZOO[name]()
    plan = compile_graph(g)
    fs = plan.fusion_stats()
    interp = plan.interp_op_counts()
    shape = tuple(1 if d is None else int(d) for d in plan.graph.inputs[0].shape)
    x = np.random.RandomState(0).randn(*shape).astype(np.float32)
    out = plan.graph.output_names[0]
    plan_off = compile_graph(g, use_fusion=False)
    bit_exact = bool(np.array_equal(
        np.asarray(plan({"x": x})[out]),
        np.asarray(plan_off({"x": x})[out])))
    return {
        "model": name,
        "fusion_stats": fs,
        "interp_op_counts": dict(sorted(interp.items())),
        "bit_exact_vs_unfused": bit_exact,
        "ok": (fs["fused_boundary_segments"] >= 1 and
               fs["integer_boundaries"] >= 1 and
               fs["boundary_bytes_saved"] > 0 and
               interp.get("MaxPool", 0) == 0 and
               interp.get("Add", 0) == 0 and
               bit_exact),
    }


def check_tune(name: str, cache_dir=None, repeats: int = 5) -> dict:
    """Regression gate for the kernel autotuner + tune cache (repro.tune).

    Three invariants, measured on ``name``:

      * **tuned is never slower**: the plan compiled with ``tune="search"``
        must reach ≥ 90% of the default-blocks plan's throughput
        (interleaved best-of timing; the search always times the default
        tiling too, so a real regression means the selection logic broke —
        the 10% headroom only absorbs timing noise);
      * **warm cache re-tunes nothing**: a second ``compile_graph`` with
        ``tune="cached"`` against the same cache dir must answer every
        kernel segment from the graph manifest — 0 searches, 0 misses,
        1 graph-manifest hit, every kernel segment tuned;
      * **warm plan re-traces nothing**: two same-shape calls of the warm
        plan must leave ``trace_count`` at 1 (one trace for the new shape,
        zero retraces — the persistent-compilation-cache story only holds
        if the plan itself is shape-stable).

    Returns a record; record["ok"] is the verdict.
    """
    g = zoo.ZOO[name]()
    shape = tuple(1 if d is None else int(d) for d in g.inputs[0].shape)
    x = np.random.RandomState(0).randn(*shape).astype(np.float32)

    plan_def = compile_graph(g)
    plan_tuned = compile_graph(g, tune="search", tune_cache_dir=cache_dir)
    out = plan_def.graph.output_names[0]
    s_def, s_tuned = time_fns(
        [lambda: jax.block_until_ready(plan_def({"x": x})[out]),
         lambda: jax.block_until_ready(plan_tuned({"x": x})[out])],
        repeats)
    speedup = s_def / s_tuned if s_tuned else float("inf")
    search_stats = plan_tuned.tuning_stats()

    # warm-cache recompile: everything answered from the manifest
    plan_warm = compile_graph(g, tune="cached", tune_cache_dir=cache_dir)
    warm = plan_warm.tuning_stats()
    warm_ok = (warm.get("searched", 0) == 0 and warm.get("misses", 0) == 0
               and warm.get("graph_hit", 0) == 1 and
               warm["kernel_segments"] >= 1 and
               warm["tuned_segments"] == warm["kernel_segments"])
    jax.block_until_ready(plan_warm({"x": x})[out])
    jax.block_until_ready(plan_warm({"x": x})[out])
    trace_ok = plan_warm.trace_count == 1

    return {
        "model": name,
        "default_us": round(s_def * 1e6, 1),
        "tuned_us": round(s_tuned * 1e6, 1),
        "tuned_speedup": round(speedup, 3),
        "search_stats": search_stats,
        "warm_stats": warm,
        "warm_trace_count": plan_warm.trace_count,
        "ok": bool(speedup >= 0.90 and warm_ok and trace_ok),
    }


def main(argv=None) -> int:
    """CLI used by the CI smoke job: exit 0 iff every row was produced and
    every ``--check-conv`` / ``--check-grouped`` /
    ``--check-integer-requant`` / ``--check-fusion`` / ``--check-tune``
    gate holds.

        python benchmarks/bench_compile.py [--quick] [--json PATH]
                                           [--check-conv MODEL ...]
                                           [--check-grouped MODEL ...]
                                           [--check-integer-requant MODEL ...]
                                           [--check-fusion MODEL ...]
                                           [--check-tune MODEL ...]
                                           [--tune-cache-dir PATH]
                                           [--metrics-snapshot PATH]
    """
    import argparse
    import json

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="TFC-only cases (fast enough for CI smoke)")
    ap.add_argument("--json", metavar="PATH",
                    help="write machine-readable results (per-model wall "
                         "time, speedup, fused-segment counts) to PATH")
    ap.add_argument("--check-conv", metavar="MODEL", action="append",
                    default=[],
                    help="assert MODEL compiles with ≥1 conv segment fused "
                         "and 0 interpreted Conv nodes (repeatable)")
    ap.add_argument("--check-grouped", metavar="MODEL", action="append",
                    default=[],
                    help="assert MODEL's grouped convs all lower onto the "
                         "grouped/depthwise kernels (0 block-diagonal "
                         "carriers) and the cost report's MAC count drops "
                         "vs the dense-equivalent number (repeatable)")
    ap.add_argument("--check-integer-requant", metavar="MODEL",
                    action="append", default=[],
                    help="assert MODEL compiles with every kernel segment "
                         "on the int32 dyadic requant epilogue (coverage "
                         "1.0, 0 fp32-requant segments; repeatable)")
    ap.add_argument("--check-fusion", metavar="MODEL", action="append",
                    default=[],
                    help="assert MODEL compiles with ≥1 fused boundary "
                         "segment on an integer inter-segment carrier, "
                         "positive boundary bytes-saved, 0 interpreted "
                         "MaxPool/Add nodes, and bit-identical output vs "
                         "use_fusion=False (repeatable)")
    ap.add_argument("--check-tune", metavar="MODEL", action="append",
                    default=[],
                    help="assert the autotuned plan reaches ≥90%% of the "
                         "default-blocks throughput and a warm-cache "
                         "recompile answers every segment with 0 searches "
                         "and 0 retraces (repeatable)")
    ap.add_argument("--tune-cache-dir", metavar="PATH", default=None,
                    help="tune-cache root for --check-tune (default "
                         "$REPRO_TUNE_CACHE_DIR or ~/.cache/repro-tune); "
                         "CI persists this dir across runs")
    ap.add_argument("--metrics-snapshot", metavar="PATH", default=None,
                    help="dump the process-wide obs metrics registry "
                         "(compile gauges, tune hit/miss counters) to PATH "
                         "as JSON")
    args = ap.parse_args(argv)
    cases = QUICK_CASES if args.quick else CASES
    rows, records = run_detailed(cases)
    for row in rows:
        print(row)

    ok = len(rows) == 4 * len(cases)
    checks, grouped_checks, requant_checks = [], [], []
    fusion_checks, tune_checks = [], []

    def _check_tune(name):
        return check_tune(name, cache_dir=args.tune_cache_dir)

    for name, check, bucket, tag in (
            [(n, check_conv_lowering, checks, "check_conv")
             for n in args.check_conv] +
            [(n, check_grouped_lowering, grouped_checks, "check_grouped")
             for n in args.check_grouped] +
            [(n, check_integer_requant, requant_checks,
              "check_integer_requant")
             for n in args.check_integer_requant] +
            [(n, check_fusion, fusion_checks, "check_fusion")
             for n in args.check_fusion] +
            [(n, _check_tune, tune_checks, "check_tune")
             for n in args.check_tune]):
        # a failing/crashing check must still reach the JSON artifact —
        # that's exactly when CI needs the diagnostics
        try:
            c = check(name)
        except Exception as e:  # noqa: BLE001  (unknown model, compile crash)
            c = {"model": name, "ok": False, "error": f"{type(e).__name__}: {e}"}
        bucket.append(c)
        verdict = "OK" if c["ok"] else "FAIL"
        if c.get("error"):
            detail = c["error"]
        elif tag == "check_integer_requant":
            rs = c["requant_stats"]
            detail = (f"coverage={rs['coverage']:.2f};"
                      f"int32={rs['int32_segments']}/"
                      f"{rs['kernel_segments']};"
                      f"fp32_ops_eliminated={rs['fp32_ops_eliminated']}")
        elif tag == "check_fusion":
            fsn = c["fusion_stats"]
            io = c["interp_op_counts"]
            detail = (f"fused_boundaries={fsn['fused_boundary_segments']};"
                      f"int_carriers={fsn['integer_boundaries']};"
                      f"packed={fsn['packed_boundaries']};"
                      f"bytes_saved={fsn['boundary_bytes_saved']};"
                      f"interp_pool={io.get('MaxPool', 0)};"
                      f"interp_add={io.get('Add', 0)};"
                      f"bit_exact={c['bit_exact_vs_unfused']}")
        elif tag == "check_tune":
            ws = c["warm_stats"]
            detail = (f"speedup={c['tuned_speedup']:.2f}x;"
                      f"warm_tuned={ws['tuned_segments']}/"
                      f"{ws['kernel_segments']};"
                      f"warm_searched={ws.get('searched', 0)};"
                      f"warm_trace_count={c['warm_trace_count']}")
        else:
            detail = f"interp_convs={c['conv_nodes_interpreted']}"
            if tag == "check_grouped":
                gs = c["grouped_stats"]
                detail += (f";block_diag={gs['block_diagonal_grouped']};"
                           f"reclaimed_macs={gs['reclaimed_macs']};"
                           f"macs={c['report_macs']}<"
                           f"dense_equiv={c['dense_equiv_macs']}")
        print(f"{tag}/{name},{c.get('conv_segments_fused', 0)},"
              f"{detail};{verdict}")
        ok = ok and c["ok"]

    if args.json:
        payload = {"models": records}
        if checks:
            payload["conv_checks"] = checks
        if grouped_checks:
            payload["grouped_checks"] = grouped_checks
        if requant_checks:
            payload["integer_requant_checks"] = requant_checks
        if fusion_checks:
            payload["fusion_checks"] = fusion_checks
        if tune_checks:
            payload["tune_checks"] = tune_checks
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")

    if args.metrics_snapshot:
        from repro.obs import default_registry
        with open(args.metrics_snapshot, "w") as f:
            f.write(default_registry().to_json(indent=2, sort_keys=True))
        print(f"# wrote {args.metrics_snapshot}")
    return 0 if ok else 1


if __name__ == "__main__":        # PYTHONPATH=src python benchmarks/bench_compile.py
    raise SystemExit(main())
