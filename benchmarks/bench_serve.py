"""Serving-tier benchmark: pipelined dispatch vs per-chunk sync + scheduler.

Two measurements over the compiled-graph serving tier (repro.serve):

  * **pipeline gap** — ``CompiledGraphEngine.__call__`` over a multi-chunk
    batch (batch 64 through max_batch-8 slots = 8 plan calls) with
    ``pipeline=True`` (all chunks dispatched device-side, one trailing
    ``block_until_ready``) vs ``pipeline=False`` (the old per-chunk
    ``np.asarray`` stall).  Throughput in requests/s, best-of-N to
    de-noise; both modes are parity-checked against each other first.
  * **scheduler latency** — submit->future round trips through a running
    ``ServeScheduler``; reports p50/p99 request latency and queue wait
    from the engine's rolling telemetry.

  * **observability overhead** — the same submit->flush workload through a
    metrics-enabled engine vs one built with ``observability=False`` (the
    pre-instrumentation baseline).  The registry work on the hot path is a
    handful of dict/lock operations per request, so the gate demands the
    instrumented engine stays within 3% of baseline throughput.

``--check`` (implied by ``--quick``, the CI smoke gate) exits non-zero
unless pipelined throughput at least matches the synchronous baseline on
every case (5% headroom absorbs shared-runner noise; the measured speedup
sits well above 1x on a quiet machine) AND the observability overhead
stays within its 3% envelope.  ``--metrics-snapshot PATH`` dumps the
bench engines' shared metrics registry as JSON (the CI artifact rendered
by ``python -m repro.obs.report``).
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.models import zoo

CASES = [("TFC-w2a2", 64, 8)]                    # (model, batch, max_batch)


def _interleaved_best_s(fns: list, repeats: int) -> list[float]:
    """Best-of-``repeats`` for each fn in alternating rounds — the shared
    ``repro.obs.profile.time_fns`` harness (kept as the historical local
    name)."""
    from repro.obs.profile import time_fns
    return time_fns(fns, repeats)


def bench_pipeline(name: str, batch: int, max_batch: int,
                   repeats: int = 15) -> dict:
    """Pipelined vs per-chunk-sync multi-chunk ``__call__`` on one model."""
    from repro.serve import CompiledGraphEngine

    eng = CompiledGraphEngine(zoo.ZOO[name](), max_batch=max_batch,
                              report_cost=False)
    x = np.random.RandomState(0).randn(
        batch, *eng.sample_shape).astype(np.float32)

    eng.pipeline = False
    ref = eng(x)
    eng.pipeline = True
    np.testing.assert_allclose(ref, eng(x), atol=1e-5)   # modes agree

    def call_sync():
        eng.pipeline = False
        eng(x)

    def call_pipe():
        eng.pipeline = True
        eng(x)

    t_sync, t_pipe = _interleaved_best_s([call_sync, call_pipe], repeats)
    return {
        "model": name, "batch": batch, "max_batch": max_batch,
        "chunks": math.ceil(batch / max_batch),
        "sync_ms": round(t_sync * 1e3, 2),
        "pipelined_ms": round(t_pipe * 1e3, 2),
        "sync_throughput_rps": round(batch / t_sync, 1),
        "pipelined_throughput_rps": round(batch / t_pipe, 1),
        "speedup": round(t_sync / t_pipe, 3),
        # the gate tolerates 5% adverse noise (shared CI runners can squeeze
        # the async-dispatch overlap); the reported speedup is the real
        # number and sits far above 1.0 on a quiet machine
        "ok": t_pipe < t_sync * 1.05,
    }


def bench_obs_overhead(name: str = "TFC-w2a2", n_requests: int = 128,
                       max_batch: int = 8, repeats: int = 7) -> dict:
    """Metrics-enabled vs ``observability=False`` submit/flush throughput.

    Both engines run the identical submit-all -> run_pending workload in
    alternating rounds; the instrumented engine must stay within 3% of the
    uninstrumented baseline (the gate CI enforces under ``--quick``).
    """
    from repro.obs import default_registry
    from repro.serve import CompiledGraphEngine

    g = zoo.ZOO[name]()
    eng_on = CompiledGraphEngine(g, max_batch=max_batch, report_cost=False,
                                 metrics_registry=default_registry(),
                                 observability=True)
    eng_off = CompiledGraphEngine(zoo.ZOO[name](), max_batch=max_batch,
                                  report_cost=False, observability=False)
    rng = np.random.RandomState(2)
    xs = [rng.randn(*eng_on.sample_shape).astype(np.float32)
          for _ in range(n_requests)]

    def mk(eng):
        def go():
            for x in xs:
                eng.submit(x)
            eng.run_pending()
        return go

    t_on, t_off = _interleaved_best_s([mk(eng_on), mk(eng_off)], repeats)
    return {
        "model": name, "n_requests": n_requests, "max_batch": max_batch,
        "obs_on_ms": round(t_on * 1e3, 2),
        "obs_off_ms": round(t_off * 1e3, 2),
        "obs_on_rps": round(n_requests / t_on, 1),
        "obs_off_rps": round(n_requests / t_off, 1),
        "overhead_pct": round((t_on / t_off - 1.0) * 100, 2),
        "ok": t_on <= t_off * 1.03,
    }


def bench_scheduler(name: str, n_requests: int = 64, max_batch: int = 8,
                    window_ms: float = 2.0) -> dict:
    """Submit->future round trips through a running ServeScheduler."""
    from repro.obs import default_registry
    from repro.serve import CompiledGraphEngine, ServeScheduler

    eng = CompiledGraphEngine(zoo.ZOO[name](), max_batch=max_batch,
                              report_cost=False,
                              metrics_registry=default_registry())
    rng = np.random.RandomState(1)
    xs = [rng.randn(*eng.sample_shape).astype(np.float32)
          for _ in range(n_requests)]
    eng(xs[0])                                   # warm the jitted slot shape
    with ServeScheduler(eng, window_ms=window_ms,
                        max_queue=max(64, n_requests)) as sched:
        t0 = time.perf_counter()
        reqs = [sched.submit(x) for x in xs]
        for r in reqs:
            r.wait(timeout=120)
        dt = time.perf_counter() - t0
    stats = eng.latency_stats()
    return {
        "model": name, "n_requests": n_requests, "max_batch": max_batch,
        "window_ms": window_ms,
        "throughput_rps": round(n_requests / dt, 1),
        "latency_p50_ms": round(stats["latency_p50_ms"], 2),
        "latency_p99_ms": round(stats["latency_p99_ms"], 2),
        "queued_p50_ms": round(stats["queued_p50_ms"], 2),
        "queued_p99_ms": round(stats["queued_p99_ms"], 2),
        "flushes": stats["flushes"],
    }


def bench_dist(name: str = "TFC-w1a1", n_requests: int = 64) -> dict:
    """Distributed-serving census + gate (``--check-dist``).

    Builds one single-device engine per local device behind a
    ``SplitMergeFront`` and checks, on a real request wave:

      * every device's worker receives dispatches (the wave actually
        shards across all N devices);
      * the merge is deterministic and submission-ordered: two runs are
        bit-identical to each other and to a single-engine oracle
        (TFC-w1a1's requant pipeline is fully integer, so ``==`` holds);
      * one injected mid-shard worker fault loses zero requests — the
        dead worker's shard is re-dispatched and the wave still matches
        the oracle bit-for-bit;
      * a mesh-sharded ``CompiledPlan`` (``mesh="auto"``) spans all
        devices and stays bit-identical to the single-device plan.
    """
    import jax

    from repro import obs
    from repro.core.compile import compile_graph
    from repro.serve import CompiledGraphEngine, SplitMergeFront, \
        device_workers

    n_devices = jax.device_count()
    reg = obs.MetricsRegistry()
    workers = device_workers(zoo.ZOO[name], metrics_registry=reg,
                             report_cost=False, max_batch=8)
    oracle_eng = CompiledGraphEngine(zoo.ZOO[name](), report_cost=False,
                                     max_batch=8)
    rng = np.random.RandomState(0)
    xs = [rng.randn(*oracle_eng.sample_shape).astype(np.float32)
          for _ in range(n_requests)]
    oracle = oracle_eng(np.stack(xs))

    with SplitMergeFront(workers, metrics_registry=reg) as front:
        t0 = time.perf_counter()
        out1 = front(xs)
        dt = time.perf_counter() - t0
        out2 = front(xs)                         # re-run: determinism
        disp = {s["labels"]["worker"]: s["value"]
                for s in reg.snapshot()
                ["splitmerge_dispatch_total"]["series"]}
        all_devices_used = (len(disp) == n_devices and
                            all(v >= 1 for v in disp.values()))
        deterministic = (np.array_equal(out1, out2) and
                         np.array_equal(out1, oracle))
        workers[-1].inject_fault()               # chaos: one worker dies
        out3 = front(xs)
        stats = front.stats()
    fault_ok = (np.array_equal(out3, oracle) and
                stats["redispatched_shards"] >= 1 and
                len(stats["failed"]) == 1)

    mesh_plan = compile_graph(zoo.ZOO[name](), mesh="auto")
    base = oracle_eng.plan
    x = {mesh_plan.graph.input_names[0]:
         rng.randn(n_requests,
                   *oracle_eng.sample_shape).astype(np.float32)}
    mesh_identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(base(dict(x)).values(), mesh_plan(x).values()))
    return {
        "model": name, "n_requests": n_requests, "devices": n_devices,
        "workers": len(workers),
        "throughput_rps": round(n_requests / dt, 1),
        "dispatch_per_worker": {k: int(v) for k, v in sorted(disp.items())},
        "all_devices_used": all_devices_used,
        "merge_deterministic": deterministic,
        "fault_injected_workers": 1,
        "fault_lost_requests": int(np.sum(
            ~np.all(out3 == oracle, axis=-1))),
        "fault_redispatched_shards": stats["redispatched_shards"],
        "fault_zero_loss": fault_ok,
        "mesh_plan_devices": mesh_plan.n_devices,
        "mesh_bit_identical": mesh_identical,
        "ok": (all_devices_used and deterministic and fault_ok and
               mesh_identical and mesh_plan.n_devices == n_devices),
    }


def run_detailed(cases=None, *, repeats: int = 15, sched_requests: int = 64
                 ) -> tuple[list[str], dict]:
    rows, records = [], {}
    for name, batch, max_batch in (CASES if cases is None else cases):
        p = bench_pipeline(name, batch, max_batch, repeats=repeats)
        rows.append(
            f"serve/{name}_call_sync_b{batch},{p['sync_ms']:.0f},"
            f"throughput={p['sync_throughput_rps']}rps;"
            f"chunks={p['chunks']}")
        rows.append(
            f"serve/{name}_call_pipelined_b{batch},{p['pipelined_ms']:.0f},"
            f"throughput={p['pipelined_throughput_rps']}rps;"
            f"speedup={p['speedup']}x")
        s = bench_scheduler(name, n_requests=sched_requests,
                            max_batch=max_batch)
        rows.append(
            f"serve/{name}_scheduler_{sched_requests}req,"
            f"{s['latency_p50_ms']:.0f},"
            f"p99={s['latency_p99_ms']:.0f}ms;"
            f"queued_p50={s['queued_p50_ms']:.0f}ms;"
            f"throughput={s['throughput_rps']}rps")
        o = bench_obs_overhead(name, n_requests=sched_requests * 2,
                               max_batch=max_batch)
        rows.append(
            f"serve/{name}_obs_overhead,{o['overhead_pct']},"
            f"on={o['obs_on_rps']}rps vs off={o['obs_off_rps']}rps")
        records[name] = {"pipeline": p, "scheduler": s, "obs_overhead": o}
    return rows, records


def run(cases=None) -> list[str]:
    """CSV rows only (the benchmarks.run aggregator protocol)."""
    return run_detailed(cases)[0]


def main(argv=None) -> int:
    """CLI used by the CI smoke job.

        python benchmarks/bench_serve.py [--quick] [--json PATH] [--check]

    ``--quick`` keeps the default TFC-batch-64 case with fewer repeats and
    scheduler requests — and implies ``--check``: exit non-zero unless the
    pipelined path's throughput beats the per-chunk-sync baseline.
    """
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="fewer repeats/requests (CI smoke); implies --check")
    ap.add_argument("--check", action="store_true",
                    help="fail unless pipelined throughput >= the sync "
                         "baseline (5%% headroom for runner noise)")
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="force N virtual host devices (sets XLA_FLAGS "
                         "before the backend initialises; CPU testing)")
    ap.add_argument("--check-dist", action="store_true",
                    help="distributed gate: the request wave must shard "
                         "across every device, merge deterministically, "
                         "and lose zero requests under one injected "
                         "worker fault")
    ap.add_argument("--dist-only", action="store_true",
                    help="run only the distributed census (implies "
                         "--check-dist); with --json, merges the census "
                         "into an existing records file")
    ap.add_argument("--json", metavar="PATH",
                    help="write machine-readable records to PATH")
    ap.add_argument("--metrics-snapshot", metavar="PATH",
                    help="write the bench engines' metrics registry "
                         "snapshot (JSON) to PATH")
    args = ap.parse_args(argv)

    if args.devices:
        # must land in XLA_FLAGS before the first backend query (imports
        # above only load modules; the backend initialises lazily)
        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()
        import jax
        if jax.device_count() < args.devices:
            print(f"check_dist,0,requested --devices {args.devices} but "
                  f"only {jax.device_count()} present (backend already "
                  f"initialised?);FAIL")
            return 1

    if args.dist_only:
        rows, records = [], {}
    else:
        rows, records = run_detailed(repeats=10 if args.quick else 15,
                                     sched_requests=32 if args.quick else 64)
    for row in rows:
        print(row)

    ok = True
    if args.check or args.quick:
        for name, rec in records.items():
            p = rec["pipeline"]
            verdict = "OK" if p["ok"] else "FAIL"
            print(f"check_pipeline/{name},{p['speedup']},"
                  f"pipelined={p['pipelined_throughput_rps']}rps vs "
                  f"sync={p['sync_throughput_rps']}rps "
                  f"(gate: >=0.95x for runner noise);{verdict}")
            ok = ok and p["ok"]
            o = rec["obs_overhead"]
            verdict = "OK" if o["ok"] else "FAIL"
            print(f"check_obs_overhead/{name},{o['overhead_pct']}%,"
                  f"on={o['obs_on_rps']}rps vs off={o['obs_off_rps']}rps "
                  f"(gate: <=3%);{verdict}")
            ok = ok and o["ok"]

    census = None
    if args.check_dist or args.dist_only:
        census = bench_dist(n_requests=32 if args.quick else 64)
        print(f"serve/dist_splitmerge_{census['model']},"
              f"{census['throughput_rps']},"
              f"devices={census['devices']};"
              f"dispatch={census['dispatch_per_worker']}")
        verdict = "OK" if census["ok"] else "FAIL"
        print(f"check_dist/{census['model']},{census['devices']},"
              f"all_devices={census['all_devices_used']};"
              f"deterministic={census['merge_deterministic']};"
              f"lost_under_fault={census['fault_lost_requests']};"
              f"mesh_identical={census['mesh_bit_identical']} "
              f"(gate: all devices used, deterministic merge, zero lost "
              f"requests, bit-identical mesh plan);{verdict}")
        ok = ok and census["ok"]

    if args.json:
        payload = {"models": records}
        if args.dist_only and os.path.exists(args.json):
            with open(args.json) as f:       # merge census into prior run
                payload = json.load(f)
        if census is not None:
            payload["dist"] = census
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")
    if args.metrics_snapshot:
        from repro.obs import default_registry
        with open(args.metrics_snapshot, "w") as f:
            f.write(default_registry().to_json(indent=2, sort_keys=True))
        print(f"# wrote {args.metrics_snapshot}")
    return 0 if ok else 1


if __name__ == "__main__":  # PYTHONPATH=src python benchmarks/bench_serve.py
    raise SystemExit(main())
