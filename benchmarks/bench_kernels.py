"""Kernel benchmarks: Pallas (interpret) vs jnp oracle, plus the analytic
TPU-side byte-traffic derivation that feeds §Perf (int4 halves weight HBM)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, n=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        fn(*args).block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def run() -> list[str]:
    rows = []
    m, k, n = 128, 1024, 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k))
    w8, s8 = ops.quantize_weights_int8(
        jax.random.normal(jax.random.PRNGKey(1), (k, n)))
    w4, s4 = ops.quantize_weights_int4(
        jax.random.normal(jax.random.PRNGKey(1), (k, n)))

    us_ref = _time(jax.jit(ref.quant_matmul_ref), x, w8, s8)
    rows.append(f"kernels/quant_matmul_ref_jnp,{us_ref:.0f},m={m};k={k};n={n}")
    us_k = _time(lambda *a: ops.quant_matmul(*a), x, w8, s8, n=2)
    rows.append(f"kernels/quant_matmul_pallas_interpret,{us_k:.0f},"
                "note=interpret-mode-python-loop;correctness-only")
    us4 = _time(jax.jit(ref.quant_matmul_int4_ref), x, w4, s4)
    rows.append(f"kernels/quant_matmul_int4_ref_jnp,{us4:.0f},"
                f"hbm_weight_bytes_int8={k * n};hbm_weight_bytes_int4={k * n // 2}")

    xq = jax.random.normal(jax.random.PRNGKey(2), (512, 1024))
    us_q = _time(jax.jit(lambda a: ref.quant_dequant_ref(a, 0.05, 0.0, 8)), xq)
    rows.append(f"kernels/quant_dequant_ref_jnp,{us_q:.0f},shape=512x1024")

    # graph-path dispatch: a Quant(w) -> MatMul graph compiled through
    # core/compile.py reaches the same kernels (fused-segment census proves
    # the lowering; the timing is the whole jitted plan)
    from repro.core import GraphBuilder
    from repro.core.compile import compile_graph
    b = GraphBuilder("qmm_graph")
    xg = b.add_input("x", (m, k))
    wname = b.add_initializer(
        "w", np.random.RandomState(3).randn(k, n).astype(np.float32) * 0.05)
    qw = b.quant(wname, 0.01, 0.0, 8, narrow=True)
    (y,) = b.add_node("MatMul", [xg, qw], 1)
    b.mark_output(y)
    plan = compile_graph(b.build())
    out_name = plan.graph.output_names[0]
    xv = jnp.asarray(np.asarray(x))
    us_g = _time(lambda a: plan({"x": a})[out_name], xv, n=2)
    fused = ";".join(f"{kk}={v}" for kk, v in sorted(plan.fused_counts.items()))
    rows.append(f"kernels/quant_matmul_graph_compiled,{us_g:.0f},{fused}")

    # analytic decode-weight-traffic table (TPU v5e, per layer matmul)
    for bits, div in (("bf16", 1), ("int8", 2), ("int4", 4)):
        bytes_w = 2 * k * n // div
        t_mem_us = bytes_w / 819e9 * 1e6
        rows.append(f"kernels/decode_weight_traffic_{bits},{t_mem_us:.3f},"
                    f"bytes={bytes_w};v5e_hbm=819GBps")
    return rows
