"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * bench_zoo     — Table III (+ Fig. 5 data): MACs/weights/bits/BOPs
  * bench_formats — Table I: lowering correctness + expressiveness gaps
  * bench_kernels — Pallas kernel oracles + TPU byte-traffic analytics
  * bench_compile — compiled plan vs node-by-node interpreter wall time
  * bench_serve   — serving tier: pipelined vs per-chunk-sync dispatch,
                    scheduler round-trip p50/p99
  * roofline      — assignment §Roofline (reads the dry-run artifacts)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_compile, bench_formats, bench_kernels,
                            bench_serve, bench_zoo, roofline)
    print("name,us_per_call,derived")
    failures = 0
    for mod in (bench_zoo, bench_formats, bench_kernels, bench_compile,
                bench_serve, roofline):
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__},0,ERROR", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
