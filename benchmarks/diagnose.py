"""HLO collective diagnostics for one dry-run cell.

  PYTHONPATH=src python -m benchmarks.diagnose --arch qwen2_1_5b \\
      --shape decode_32k [--shard-acts] [--embed-dshard] [--top 15]

Prints the top-N collectives by result bytes with their HLO lines — the
"profile" of the dry-run methodology (no real hardware): every hillclimb
hypothesis starts from this list.

Compiled-graph segment profiling (measured, not dry-run):

  PYTHONPATH=src python -m benchmarks.diagnose --profile CNV-w1a1 \\
      [--repeats 20] [--bw-gbps 819] [--batch 1]

Times every fused segment of the zoo model's compiled plan
(``CompiledPlan.profile``) and prints the measured-ms / MACs/s /
minimal-vs-achieved-bytes / requant table with the roofline column.
"""
import os
import sys

if "--profile" not in sys.argv:
    # the collective dry-run needs a big fake device mesh; the measured
    # --profile path must run on the real (single-device) backend
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import re

COLL_RE = re.compile(
    r"(?:ROOT )?%?([\w\.\-]+) = (.*?) (all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(-start)?\(")


def top_collectives(hlo: str, n=15):
    from repro.launch.dryrun import _shape_bytes

    rows = []
    for line in hlo.splitlines():
        ls = line.strip()
        m = COLL_RE.match(ls)
        if not m or "-done(" in ls:
            continue
        rows.append((_shape_bytes(m.group(2)), m.group(3), ls[:240]))
    rows.sort(reverse=True)
    return rows[:n]


def profile_model(args) -> None:
    """--profile MODEL: measured per-segment table for a zoo graph."""
    import numpy as np

    from repro.core.compile import compile_graph
    from repro.models import zoo

    g = zoo.ZOO[args.profile]()
    plan = compile_graph(g)
    x = None
    if args.batch != 1:
        shape = (args.batch,) + tuple(
            1 if d is None else int(d) for d in g.inputs[0].shape)[1:]
        x = np.random.RandomState(0).randn(*shape).astype(np.float32)
    prof = plan.profile(x, repeats=args.repeats, bw_gbps=args.bw_gbps)
    print(plan.describe())
    print()
    print(prof.table())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--shard-acts", action="store_true")
    ap.add_argument("--embed-dshard", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--quant", default="w8a8")
    # measured segment profiling of a compiled zoo graph
    ap.add_argument("--profile", metavar="MODEL",
                    help="print the per-segment measured profile of a zoo "
                         "model's compiled plan instead of the dry-run "
                         "collective diagnostics")
    ap.add_argument("--repeats", type=int, default=20,
                    help="--profile timing repeats per segment (best-of)")
    ap.add_argument("--bw-gbps", type=float, default=None,
                    help="--profile roofline peak memory bandwidth in GB/s "
                         "(e.g. 819 for the roofline.py HBM model)")
    ap.add_argument("--batch", type=int, default=1,
                    help="--profile input batch size")
    args = ap.parse_args()

    if args.profile:
        profile_model(args)
        return
    if not args.arch or not args.shape:
        ap.error("--arch and --shape are required (unless --profile)")

    from repro.launch.dryrun import arch_config, collective_bytes, lower_cell
    from repro.launch.mesh import make_production_mesh

    cfg = arch_config(args.arch, args.shape, args.quant,
                      shard_acts=args.shard_acts)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    kw = {}
    if args.embed_dshard:
        kw = {"fsdp_exclude": ("embed", "lm_head")}
    lowered, _ = lower_cell(cfg, args.shape, mesh,
                            microbatches=args.microbatches, **kw)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    cb = collective_bytes(hlo)
    print(f"total collective result bytes: {cb['total_bytes'] / 1e9:.2f} GB")
    print(f"by type: "
          f"{ {k: round(v / 1e9, 2) for k, v in cb['bytes'].items() if v} }")
    print(f"counts : { {k: v for k, v in cb['counts'].items() if v} }\n")
    for size, op, line in top_collectives(hlo, args.top):
        print(f"{size / 1e9:8.3f} GB  {op:18s} {line[:200]}")
    ca = compiled.cost_analysis() or {}
    print(f"\nflops={ca.get('flops', 0):.4g}  "
          f"bytes={ca.get('bytes accessed', 0):.4g}")
    ma = compiled.memory_analysis()
    if ma:
        print(f"temp={getattr(ma, 'temp_size_in_bytes', 0) / 1e9:.2f} GB  "
              f"args={getattr(ma, 'argument_size_in_bytes', 0) / 1e9:.2f} GB")


if __name__ == "__main__":
    main()
