"""Table III / Fig. 5 benchmark: model zoo cost accounting + execution."""
from __future__ import annotations

import time

import numpy as np

from repro.core import bops, execute, transforms
from repro.models import zoo


def run() -> list[str]:
    rows = []
    for name, build in zoo.ZOO.items():
        g = transforms.infer_shapes(build())
        c = bops.graph_cost(g)
        first_conv = next((l for l in c.layers if "Conv" in l.name), None)
        conv_net = "CNV" in name or "MobileNet" in name
        macs = c.macs - (first_conv.macs if conv_net else 0)
        # µs/call of the node-level executor (the paper's "slow but
        # verifiable" engine) on a single input
        shape = ((1, 784) if "TFC" in name else
                 (1, 3, 32, 32) if "CNV" in name else (1, 3, 224, 224))
        x = np.random.RandomState(0).randn(*shape).astype(np.float32)
        execute(g, {"x": x})                       # warm
        t0 = time.perf_counter()
        n = 3 if "MobileNet" in name else 10
        for _ in range(n):
            execute(g, {"x": x})
        us = (time.perf_counter() - t0) / n * 1e6
        ref = zoo.TABLE3[name]
        rows.append(
            f"zoo/{name},{us:.0f},macs={macs};weights={c.weights};"
            f"wbits={int(c.total_weight_bits)};bops_eq5={c.bops:.3g};"
            f"table3_macs={ref[0]};match={abs(macs - ref[0]) / ref[0] < 2e-3}")
    return rows
