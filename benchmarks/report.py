"""Regenerate the EXPERIMENTS.md data tables from experiments/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.report            # print all tables
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks import roofline

DRYRUN_DIR = roofline.DRYRUN_DIR


def dryrun_table(quant="w8a8") -> str:
    """§Dry-run: compile status + memory per device for every cell/mesh."""
    rows = {}
    for p in sorted(DRYRUN_DIR.glob(f"*_{quant}.json")):
        rec = json.loads(p.read_text())
        if rec.get("tag"):
            continue
        key = (rec["arch"], rec["shape"])
        rows.setdefault(key, {})[rec["mesh"]] = rec
    lines = ["| arch | shape | single-pod 16x16 | multi-pod 2x16x16 | "
             "bytes/device (single) | collective bytes/step (single) |",
             "|---|---|---|---|---|---|"]
    for (arch, shape), d in sorted(rows.items()):
        cells = []
        for mesh in ("single", "multi"):
            r = d.get(mesh)
            if r is None:
                cells.append("—")
            elif r["status"] == "ok":
                cells.append(f"ok ({r.get('compile_s', 0):.0f}s)")
            elif r["status"] == "skipped":
                cells.append("skip")
            else:
                cells.append("FAIL")
        r = d.get("single", {})
        ma = r.get("memory_analysis", {})
        mem = ma.get("argument_size_in_bytes", 0) + ma.get(
            "temp_size_in_bytes", 0)
        coll = r.get("collectives", {}).get("total_bytes", 0)
        lines.append(f"| {arch} | {shape} | {cells[0]} | {cells[1]} | "
                     f"{mem / 1e9:.2f} GB | {coll / 1e9:.2f} GB |")
    return "\n".join(lines)


def failures(quant="w8a8") -> list[str]:
    out = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec["status"] == "failed":
            out.append(f"{p.name}: {rec.get('error', '?')}")
    return out


def main():
    print("## Dry-run table\n")
    print(dryrun_table())
    print("\n## Roofline table (paper-faithful baseline)\n")
    print(roofline.markdown_table(tag="roofline"))
    print("\n## Roofline table (optimized: --shard-acts, beyond-paper)\n")
    print(roofline.markdown_table(tag="opt"))
    f = failures()
    print(f"\nfailures: {len(f)}")
    for line in f:
        print("  ", line)


if __name__ == "__main__":
    main()
