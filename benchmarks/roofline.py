"""Roofline analysis (assignment deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and derives
the three roofline terms per (arch x shape x mesh):

    compute    = HLO_FLOPs_per_chip / 197e12      (TPU v5e bf16 peak)
    memory     = HLO_bytes_per_chip / 819e9       (HBM bandwidth)
    collective = collective_bytes_per_chip / 50e9 (ICI per link)

cost_analysis() of the SPMD-partitioned module is already per chip, so no
further division by chip count is needed.  MODEL_FLOPS uses 6*N*D for
training (3 matmul passes), 2*N*D for prefill/decode (forward only), with
N_active for MoE.  The ratio MODEL_FLOPS/HLO_FLOPs exposes remat/dispatch
overhead (values < 1 mean the compiled program does extra work: remat
recompute, quant ops, attention, dispatch scatter...).
"""
from __future__ import annotations

import json
from pathlib import Path

# hardware model shared with the kernel autotuner's pruning cost model
from repro.tune.roofline import HBM_BW, ICI_BW, PEAK_FLOPS  # noqa: F401

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def _chips(mesh: str) -> int:
    return 512 if mesh == "multi" else 256


def model_flops_per_chip(rec: dict) -> float:
    """6ND train / 2ND inference, N(_active), D = tokens processed."""
    from repro.configs import get_config
    from repro.models import api
    cfg = get_config(rec["arch"])
    sh = api.SHAPES[rec["shape"]]
    n_params = cfg.active_param_count() if cfg.family == "moe" else \
        cfg.param_count()
    kind = sh["kind"]
    tokens = sh["global_batch"] * (sh["seq_len"] if kind != "decode" else 1)
    if cfg.family == "vlm" and kind != "decode":
        tokens += sh["global_batch"] * cfg.n_patches
    factor = 6 if kind == "train" else 2
    return factor * n_params * tokens / _chips(rec["mesh"])


def analyze(rec: dict) -> dict:
    ca = rec.get("cost_analysis", {})
    flops = ca.get("flops", 0.0)
    bytes_ = ca.get("bytes accessed", 0.0)
    coll = rec.get("collectives", {}).get("total_bytes", 0)
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_x = coll / ICI_BW
    dominant = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                   key=lambda kv: kv[1])[0]
    mf = model_flops_per_chip(rec)
    bound = max(t_c, t_m, t_x)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dominant,
        "model_flops_per_chip": mf,
        "hlo_flops_per_chip": flops,
        "useful_ratio": (mf / flops) if flops else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
        "status": rec["status"],
    }


def load_records(mesh="single", quant="w8a8", tag="roofline"):
    """Roofline terms come from the unrolled-scan ("roofline"-tagged)
    lowerings; the untagged records are the production dry-run proof."""
    recs = []
    suffix = f"_{tag}" if tag else ""
    for p in sorted(DRYRUN_DIR.glob(f"*_{mesh}_{quant}{suffix}.json")):
        rec = json.loads(p.read_text())
        if (rec.get("tag") or "") != tag:
            continue
        recs.append(rec)
    return recs


def run() -> list[str]:
    rows = []
    for tag, label in (("roofline", "baseline"), ("opt", "optimized")):
        for rec in load_records("single", tag=tag):
            name = f"roofline-{label}/{rec['arch']}/{rec['shape']}"
            if rec["status"] == "skipped":
                rows.append(f"{name},0,skipped")
                continue
            if rec["status"] != "ok":
                rows.append(f"{name},0,FAILED")
                continue
            a = analyze(rec)
            bound_us = max(a["t_compute_s"], a["t_memory_s"],
                           a["t_collective_s"]) * 1e6
            rows.append(
                f"{name},{bound_us:.1f},"
                f"tc={a['t_compute_s']:.2e};tm={a['t_memory_s']:.2e};"
                f"tx={a['t_collective_s']:.2e};dom={a['dominant']};"
                f"useful={a['useful_ratio']:.2f};"
                f"roofline_frac={a['roofline_fraction']:.2f}")
    return rows


def markdown_table(mesh="single", quant="w8a8", tag="roofline") -> str:
    lines = ["| arch | shape | t_compute | t_memory | t_collective | "
             "dominant | useful (6ND/HLO) | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for rec in load_records(mesh, quant, tag):
        if rec["status"] == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                         f"skipped (sub-quadratic req.) | — | — |")
            continue
        if rec["status"] != "ok":
            lines.append(f"| {rec['arch']} | {rec['shape']} | FAILED |||||||")
            continue
        a = analyze(rec)
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.2e}s | "
            f"{a['t_memory_s']:.2e}s | {a['t_collective_s']:.2e}s | "
            f"**{a['dominant']}** | {a['useful_ratio']:.2f} | "
            f"{a['roofline_fraction']:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
