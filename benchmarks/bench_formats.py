"""Table I benchmark: format conversions over the zoo — correctness of each
lowering + conversion wall time + graph size deltas.

Lowered graphs execute on the *compiled* tier (core/compile.py) and are
checked against the interpreted oracle of the source graph, so every
conversion row also exercises the kernel-lowered path end to end."""
from __future__ import annotations

import time

import numpy as np

from repro.core import execute, transforms
from repro.core.compile import compile_graph
from repro.core.formats import (UnsupportedLowering, qcdq_to_qonnx,
                                qonnx_to_qcdq, qonnx_to_quantized_op)
from repro.models import zoo


def _maxdiff(g1, g2, shape):
    """Interpreted oracle of g1 vs *compiled* execution of g2."""
    x = np.random.RandomState(0).randn(*shape).astype(np.float32)
    o1 = execute(g1, {"x": x})[g1.output_names[0]]
    o2 = compile_graph(g2)({g2.input_names[0]: x})[g2.output_names[0]]
    return float(np.max(np.abs(np.asarray(o1) - np.asarray(o2))))


def run() -> list[str]:
    rows = []
    for name in ["TFC-w2a2", "CNV-w2a2", "TFC-w1a1"]:
        g = transforms.cleanup(zoo.ZOO[name]())
        shape = (1, 784) if "TFC" in name else (1, 3, 32, 32)
        for fmt, conv in [("qcdq", qonnx_to_qcdq),
                          ("quantized_op", qonnx_to_quantized_op)]:
            t0 = time.perf_counter()
            try:
                g2 = conv(g)
                us = (time.perf_counter() - t0) * 1e6
                diff = _maxdiff(g, g2, shape)
                rows.append(f"formats/{name}->{fmt},{us:.0f},"
                            f"maxdiff={diff:.2e};nodes={len(g2.nodes)}")
                if fmt == "qcdq":
                    g3 = qcdq_to_qonnx(g2)
                    diff_rt = _maxdiff(g, g3, shape)
                    rows.append(f"formats/{name}->qcdq->qonnx,0,"
                                f"roundtrip_maxdiff={diff_rt:.2e}")
            except UnsupportedLowering as e:
                us = (time.perf_counter() - t0) * 1e6
                rows.append(f"formats/{name}->{fmt},{us:.0f},"
                            f"unsupported(TableI)={str(e)[:60]!r}")
    return rows
